"""Calibrated per-device constants (ROADMAP item 3).

The cost model's three hand-set constants — the scalar
``mxu_efficiency``, the datasheet ``ClusterLevel`` alpha/bandwidth
pairs, and the 1.30 recompute factor — are exactly the quantities a
timed micro-benchmark can measure.  This module defines the fitted
replacements:

* :class:`EfficiencyCurve` — achieved fraction of peak flops as a
  piecewise-linear function of matmul size (log10 flops), replacing
  the single scalar derating.
* :class:`LinkCalibration` — a fitted (alpha, bandwidth) pair for one
  named ``ClusterLevel``, from an alpha-beta fit over message sizes.
* :class:`CalibrationProfile` — the serializable bundle attached to a
  ``CostEnv``.  ``profile=None`` everywhere keeps the legacy scalar
  path byte-identical; every committed golden is pinned on it.

Nothing here imports jax or any other repro module: the profile is a
plain value type so `configs`, `core` and `cluster` can consume it
without import cycles.  The timed benchmarks live in
:mod:`repro.calibrate.bench`; the fitting math in
:mod:`repro.calibrate.fit`.
"""
from __future__ import annotations

import json
import math
from dataclasses import dataclass
from typing import Dict, Optional, Tuple


@dataclass(frozen=True)
class EfficiencyCurve:
    """Achieved fraction of peak compute vs operator size.

    Knots are ``log10(flops)`` of the measured matmuls; ``fraction``
    holds the achieved/peak ratio at each knot.  The curve is pinned
    monotone non-decreasing (bigger ops amortize launch/memory
    overheads at least as well) and clamped to its endpoint values
    outside the measured range, so an extrapolated query can never
    invent an efficiency the benchmark did not observe.
    """

    log10_flops: Tuple[float, ...]
    fraction: Tuple[float, ...]

    def __post_init__(self):
        if len(self.log10_flops) != len(self.fraction):
            raise ValueError("knot/fraction length mismatch: "
                             f"{len(self.log10_flops)} vs "
                             f"{len(self.fraction)}")
        if not self.log10_flops:
            raise ValueError("EfficiencyCurve needs at least one knot")
        for a, b in zip(self.log10_flops, self.log10_flops[1:]):
            if not b > a:
                raise ValueError("knots must be strictly increasing")
        for a, b in zip(self.fraction, self.fraction[1:]):
            if b < a:
                raise ValueError("fractions must be non-decreasing "
                                 "(fit with calibrate.fit to enforce)")
        for f in self.fraction:
            if not 0.0 < f <= 1.0:
                raise ValueError(f"fraction {f} outside (0, 1]")

    @classmethod
    def constant(cls, fraction: float) -> "EfficiencyCurve":
        """Degenerate one-knot curve: the legacy scalar efficiency."""
        return cls((0.0,), (float(fraction),))

    def at(self, flops: float) -> float:
        """Achieved fraction of peak for an operator of ``flops``
        total work, clamped to the measured range."""
        xs, ys = self.log10_flops, self.fraction
        if len(xs) == 1:
            return ys[0]
        x = math.log10(flops) if flops > 0 else xs[0]
        if x <= xs[0]:
            return ys[0]
        if x >= xs[-1]:
            return ys[-1]
        # knot count is small (benchmark sweep sizes); linear scan
        for i in range(1, len(xs)):
            if x <= xs[i]:
                t = (x - xs[i - 1]) / (xs[i] - xs[i - 1])
                return ys[i - 1] + t * (ys[i] - ys[i - 1])
        return ys[-1]   # pragma: no cover - unreachable


@dataclass(frozen=True)
class LinkCalibration:
    """Fitted alpha-beta constants for one cluster level.

    ``t(B) = alpha + B / bandwidth`` — ``alpha`` is the per-ring-step
    latency in seconds, ``bandwidth`` the achieved (not datasheet)
    bytes/s, both from a least-squares fit over a message-size sweep.
    ``level`` names the ``ClusterLevel`` this applies to ("data",
    "pod", "node", ...).
    """

    level: str
    alpha: float
    bandwidth: float

    def __post_init__(self):
        if self.alpha < 0:
            raise ValueError(f"negative alpha {self.alpha}")
        if self.bandwidth <= 0:
            raise ValueError(f"non-positive bandwidth {self.bandwidth}")


@dataclass(frozen=True)
class CalibrationProfile:
    """Measured replacements for the cost model's assumed constants.

    Attach to a ``CostEnv`` via ``CostEnv(..., profile=profile)``.
    ``device`` names the preset the numbers were measured for;
    ``peak_flops`` records what peak the efficiency fractions were
    normalized against (informational — pricing always uses the
    env's ``DeviceInfo.peak_flops``).
    """

    device: str
    efficiency: EfficiencyCurve
    links: Tuple[LinkCalibration, ...] = ()
    remat_factor: float = 1.30
    peak_flops: Optional[float] = None
    source: str = ""

    def __post_init__(self):
        if not 1.0 <= self.remat_factor <= 3.0:
            raise ValueError(
                f"remat_factor {self.remat_factor} outside [1, 3]")
        names = [ln.level for ln in self.links]
        if len(names) != len(set(names)):
            raise ValueError(f"duplicate link levels: {names}")

    # -- JSON round-trip ---------------------------------------------------
    def to_dict(self) -> Dict:
        return {
            "device": self.device,
            "efficiency": {
                "log10_flops": list(self.efficiency.log10_flops),
                "fraction": list(self.efficiency.fraction),
            },
            "links": [
                {"level": ln.level, "alpha": ln.alpha,
                 "bandwidth": ln.bandwidth}
                for ln in self.links
            ],
            "remat_factor": self.remat_factor,
            "peak_flops": self.peak_flops,
            "source": self.source,
        }

    @classmethod
    def from_dict(cls, d: Dict) -> "CalibrationProfile":
        eff = d["efficiency"]
        return cls(
            device=d["device"],
            efficiency=EfficiencyCurve(tuple(eff["log10_flops"]),
                                       tuple(eff["fraction"])),
            links=tuple(LinkCalibration(ln["level"], ln["alpha"],
                                        ln["bandwidth"])
                        for ln in d.get("links", ())),
            remat_factor=d.get("remat_factor", 1.30),
            peak_flops=d.get("peak_flops"),
            source=d.get("source", ""),
        )

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "CalibrationProfile":
        return cls.from_dict(json.loads(text))

    def save(self, path) -> None:
        with open(path, "w") as f:
            f.write(self.to_json() + "\n")

    @classmethod
    def load(cls, path) -> "CalibrationProfile":
        with open(path) as f:
            return cls.from_json(f.read())


def default_profile(device) -> CalibrationProfile:
    """The scalar constants of a ``DeviceInfo``, expressed as a
    (degenerate) profile: constant efficiency curve at
    ``mxu_efficiency``, no fitted links, the hand-set 1.30 recompute
    factor.  Attaching it to a ``CostEnv`` reproduces the legacy
    ``profile=None`` numbers to ~1e-15 relative (the only difference
    is ``remat_factor - 1.0`` vs the literal ``0.30`` in the
    selective-remat slope, one ulp apart)."""
    return CalibrationProfile(
        device=device.name,
        efficiency=EfficiencyCurve.constant(device.mxu_efficiency),
        remat_factor=1.30,
        peak_flops=device.peak_flops,
        source="datasheet",
    )
