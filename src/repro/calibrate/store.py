"""The profile store: one place where fitted constants override the
preset catalog.

``configs.base.PRESET_CATALOG`` is the single source of the datasheet
constants (DeviceInfo + achievable overlap per preset).  This module
layers fitted :class:`CalibrationProfile` objects on top: ``resolve``
answers "what constants should price device X" — a registered fitted
profile if one exists, else the catalog's scalar constants expressed
as a degenerate profile.  Nothing else in the tree caches per-device
constants, so a fitted profile overrides in exactly one place.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

from repro.calibrate.profile import CalibrationProfile, default_profile
from repro.configs import DeviceInfo

_REGISTRY: Dict[str, CalibrationProfile] = {}


def register(profile: CalibrationProfile) -> None:
    """Install a fitted profile for ``profile.device`` (overrides the
    catalog default until :func:`clear`)."""
    _REGISTRY[profile.device] = profile


def registered(name: str) -> Optional[CalibrationProfile]:
    """The fitted profile for ``name``, or None if none installed."""
    return _REGISTRY.get(name)


def registered_names() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def clear() -> None:
    """Drop all registered profiles (tests)."""
    _REGISTRY.clear()


def catalog_default(name: str) -> CalibrationProfile:
    """The preset catalog's scalar constants for ``name`` as a
    degenerate profile (constant efficiency curve, 1.30 remat, no
    fitted links).  Raises KeyError for unknown presets, matching
    ``DeviceInfo.preset``."""
    return default_profile(DeviceInfo.preset(name))


def resolve(name: str) -> CalibrationProfile:
    """The constants that should price device ``name``: the fitted
    profile if registered, else the catalog default."""
    got = _REGISTRY.get(name)
    return got if got is not None else catalog_default(name)


def load_and_register(path) -> CalibrationProfile:
    """Load a profile JSON (as written by `repro calibrate --out`) and
    install it."""
    profile = CalibrationProfile.load(path)
    register(profile)
    return profile
