"""Timed micro-benchmarks behind `repro calibrate`.

Three sweeps, mirroring the three fitted constants:

* :func:`matmul_sweep` — square matmuls over a size ladder; feeds
  :func:`repro.calibrate.fit.fit_efficiency_curve`.
* :func:`collective_sweep` — the perf_probe all-gather timing swept
  over message sizes per mesh axis; feeds
  :func:`repro.calibrate.fit.fit_link_calibrations`.
* :func:`remat_sweep` — grad of a deep matmul chain, plain vs
  ``jax.checkpoint`` per layer; feeds
  :func:`repro.calibrate.fit.fit_remat_factor`.

jax is imported inside the functions (never at module import), so the
caller controls ``XLA_FLAGS`` (fake-device count) before the first
timed call — the same contract as ``launch/perf_probe.py``.
"""
from __future__ import annotations

import time
from typing import Dict, List, Sequence, Tuple

DEFAULT_MATMUL_SIZES = (64, 128, 256, 512, 1024)
DEFAULT_BW_MIB = (0.25, 1.0, 4.0, 16.0)


def _median_time(fn, *args, repeats: int = 3) -> float:
    """Median wall-clock of ``fn(*args)`` after one warmup call
    (compile + cache), via ``block_until_ready``."""
    import jax
    jax.block_until_ready(fn(*args))
    times = []
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def matmul_sweep(sizes: Sequence[int] = DEFAULT_MATMUL_SIZES,
                 repeats: int = 3) -> List[Tuple[float, float]]:
    """Time jit'd square f32 matmuls; returns (total_flops, seconds)
    samples sized for :func:`fit.fit_efficiency_curve`."""
    import jax
    import jax.numpy as jnp

    f = jax.jit(lambda a, b: a @ b)
    out = []
    key = jax.random.PRNGKey(0)
    for n in sizes:
        ka, kb = jax.random.split(jax.random.fold_in(key, n))
        a = jax.random.normal(ka, (n, n), jnp.float32)
        b = jax.random.normal(kb, (n, n), jnp.float32)
        dt = _median_time(f, a, b, repeats=repeats)
        out.append((2.0 * n * n * n, dt))
    return out


def measured_peak_flops(samples: Sequence[Tuple[float, float]]) -> float:
    """Best achieved FLOP/s across a matmul sweep — the natural peak
    to normalize an efficiency curve against when no datasheet number
    exists for the backend (CPU emulation)."""
    return max(flops / seconds for flops, seconds in samples)


def collective_sweep(mesh, sizes_mib: Sequence[float] = DEFAULT_BW_MIB,
                     repeats: int = 3) -> Dict[str, List[Tuple[float, float]]]:
    """Per-axis (bytes_moved, seconds) samples over a message-size
    ladder, via ``perf_probe.measure_level_bandwidth``.  Span-1 axes
    come back empty (they move no bytes)."""
    from repro.launch.perf_probe import measure_level_bandwidth

    out: Dict[str, List[Tuple[float, float]]] = {
        str(a): [] for a in mesh.axis_names}
    for mib in sizes_mib:
        rec = measure_level_bandwidth(mesh, size_mib=mib, repeats=repeats)
        for axis, row in rec.items():
            if row["bytes_moved"] > 0:
                out[str(axis)].append(
                    (float(row["bytes_moved"]), float(row["seconds"])))
    return out


def remat_sweep(depth: int = 8, width: int = 256, batch: int = 64,
                repeats: int = 3) -> Tuple[float, float]:
    """(plain_seconds, remat_seconds) for one grad step of a
    ``depth``-layer matmul+tanh chain — the remat variant wraps each
    layer in ``jax.checkpoint`` so the backward pass recomputes every
    forward activation, which is exactly what the cost model's
    recompute factor charges for."""
    import jax
    import jax.numpy as jnp

    key = jax.random.PRNGKey(1)
    ws = [jax.random.normal(jax.random.fold_in(key, i), (width, width),
                            jnp.float32) / jnp.sqrt(width)
          for i in range(depth)]
    x = jax.random.normal(jax.random.fold_in(key, depth), (batch, width),
                          jnp.float32)

    def layer(w, h):
        return jnp.tanh(h @ w)

    def loss_plain(ws, x):
        h = x
        for w in ws:
            h = layer(w, h)
        return jnp.sum(h * h)

    ckpt_layer = jax.checkpoint(layer)

    def loss_remat(ws, x):
        h = x
        for w in ws:
            h = ckpt_layer(w, h)
        return jnp.sum(h * h)

    g_plain = jax.jit(jax.grad(loss_plain))
    g_remat = jax.jit(jax.grad(loss_remat))
    t_plain = _median_time(g_plain, ws, x, repeats=repeats)
    t_remat = _median_time(g_remat, ws, x, repeats=repeats)
    return t_plain, t_remat
