"""Calibration subsystem: measure, fit, and ship per-device constants.

`repro calibrate` (launch/calibrate.py) runs the timed sweeps in
:mod:`repro.calibrate.bench`, fits them with
:mod:`repro.calibrate.fit`, and writes a
:class:`~repro.calibrate.profile.CalibrationProfile` that plugs into
``CostEnv(..., profile=...)``.  ``profile=None`` keeps the legacy
scalar constants byte-identical.
"""
from repro.calibrate.profile import (CalibrationProfile, EfficiencyCurve,
                                     LinkCalibration, default_profile)
from repro.calibrate.fit import (fit_alpha_beta, fit_efficiency_curve,
                                 fit_link_calibrations, fit_remat_factor)
from repro.calibrate import store

__all__ = [
    "CalibrationProfile", "EfficiencyCurve", "LinkCalibration",
    "default_profile", "fit_alpha_beta", "fit_efficiency_curve",
    "fit_link_calibrations", "fit_remat_factor", "store",
]
