"""Fitting timed samples to the cost model's constants.

Three fits, all closed-form numpy (no scipy):

* :func:`fit_efficiency_curve` — (flops, seconds) matmul samples to a
  monotone achieved-fraction-of-peak curve (isotonic regression via
  pool-adjacent-violators).
* :func:`fit_alpha_beta` — (bytes, seconds) collective samples to the
  classic ``t = alpha + B/bw`` latency/bandwidth model (least squares
  with non-negativity clamps).
* :func:`fit_remat_factor` — plain vs remat'd step times to the
  recompute factor, clamped to the model's sane range.
"""
from __future__ import annotations

from typing import Dict, Iterable, Sequence, Tuple

import numpy as np

from repro.calibrate.profile import EfficiencyCurve, LinkCalibration


def _pava_non_decreasing(y: np.ndarray, w: np.ndarray) -> np.ndarray:
    """Weighted isotonic regression (non-decreasing), pool-adjacent-
    violators: the least-squares monotone fit to ``y``."""
    vals = list(map(float, y))
    wts = list(map(float, w))
    # each block: [value, weight, count]
    blocks = [[v, wt, 1] for v, wt in zip(vals, wts)]
    out = []
    for b in blocks:
        out.append(b)
        while len(out) > 1 and out[-2][0] > out[-1][0]:
            v2, w2, c2 = out.pop()
            v1, w1, c1 = out.pop()
            wt = w1 + w2
            out.append([(v1 * w1 + v2 * w2) / wt, wt, c1 + c2])
    fitted = []
    for v, _, c in out:
        fitted.extend([v] * c)
    return np.asarray(fitted)


def fit_efficiency_curve(samples: Iterable[Tuple[float, float]],
                         peak_flops: float) -> EfficiencyCurve:
    """Fit (total_flops, seconds) matmul timings to an
    :class:`EfficiencyCurve`.

    Achieved fraction per sample is ``flops / seconds / peak``;
    duplicate sizes are averaged, the sequence is made monotone
    non-decreasing in size (isotonic regression), and fractions are
    clipped into ``(0, 1]`` so measurement noise above peak cannot
    leak >1 efficiencies into the planner.
    """
    by_size: Dict[float, list] = {}
    for flops, seconds in samples:
        if flops <= 0 or seconds <= 0:
            raise ValueError(f"bad sample ({flops}, {seconds})")
        by_size.setdefault(float(flops), []).append(
            flops / seconds / peak_flops)
    if not by_size:
        raise ValueError("no samples")
    sizes = np.array(sorted(by_size))
    frac = np.array([np.mean(by_size[s]) for s in sizes])
    wts = np.array([len(by_size[s]) for s in sizes], dtype=float)
    frac = _pava_non_decreasing(frac, wts)
    frac = np.clip(frac, 1e-9, 1.0)
    # isotonic fit can leave equal-valued plateaus; knots only need
    # the breakpoints, but keeping every size keeps .at() exact there
    return EfficiencyCurve(tuple(map(float, np.log10(sizes))),
                           tuple(map(float, frac)))


def fit_alpha_beta(samples: Sequence[Tuple[float, float]],
                   ) -> Tuple[float, float]:
    """Least-squares fit of (bytes, seconds) to ``t = alpha + B/bw``.

    Returns ``(alpha, bandwidth)``.  If the fitted intercept is
    negative (noise at small sizes), alpha is clamped to 0 and the
    slope refit through the origin.  Needs >= 2 distinct sizes.
    """
    b = np.array([float(s[0]) for s in samples])
    t = np.array([float(s[1]) for s in samples])
    if len(b) < 2 or len(set(b.tolist())) < 2:
        raise ValueError("alpha-beta fit needs >= 2 distinct sizes")
    if (t <= 0).any() or (b < 0).any():
        raise ValueError("non-positive time or negative size sample")
    slope, alpha = np.polyfit(b, t, 1)
    if alpha < 0:
        alpha = 0.0
        slope = float(np.dot(b, t) / np.dot(b, b))
    if slope <= 0:
        # latency-dominated sweep: bandwidth unresolvable, report the
        # best single-sample bound instead of a negative slope
        slope = float(np.min(t / np.maximum(b, 1.0)))
    return float(alpha), float(1.0 / slope)


def fit_link_calibrations(sweeps: Dict[str, Sequence[Tuple[float, float]]],
                          ) -> Tuple[LinkCalibration, ...]:
    """Fit one :class:`LinkCalibration` per level from per-level
    (bytes, seconds) sweeps; levels with < 2 distinct sizes are
    skipped (span-1 axes move no bytes)."""
    out = []
    for level, samples in sweeps.items():
        sizes = {float(s[0]) for s in samples}
        if len(sizes) < 2:
            continue
        alpha, bw = fit_alpha_beta(samples)
        out.append(LinkCalibration(level, alpha, bw))
    return tuple(out)


def fit_remat_factor(plain_seconds: float, remat_seconds: float,
                     lo: float = 1.0, hi: float = 2.0) -> float:
    """Recompute factor from paired step timings: the measured
    ``remat/plain`` ratio, clamped to ``[lo, hi]`` (a factor below 1
    is measurement noise; above 2 would mean recompute cost exceeds
    the whole forward+backward, which the checkpointing scheme cannot
    produce)."""
    if plain_seconds <= 0 or remat_seconds <= 0:
        raise ValueError("non-positive step time")
    return float(min(hi, max(lo, remat_seconds / plain_seconds)))
