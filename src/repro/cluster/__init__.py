"""Hierarchical cluster topology for topology-aware planning."""
from repro.cluster.topology import (  # noqa: F401
    ClusterLevel, ClusterSpec, DeviceGroup, gpu_cluster, level_mode,
    mixed_memory_fleet, parse_level_mode, tpu_multipod)
