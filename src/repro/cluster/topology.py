"""Hierarchical cluster topology — the paper's "device information" DI,
generalized from a flat two-bandwidth model to a real hierarchy.

The paper frames OSDP as "given the model description and the device
information, generate the distributed computation graph".  Until this
module, "device information" was a flat `DeviceInfo` (one ICI and one
DCI bandwidth): the search could not see chip -> node -> pod -> cluster
structure, and collectives crossing several link classes were priced at
the bottleneck bandwidth of the whole span (GSPMD / AutoDDL both show
that is what drives mis-placement at scale).

A `ClusterSpec` is an ordered list of `ClusterLevel`s, **innermost
(fastest) first**, each with a fan-out `ways`, a per-link `bandwidth`,
and a per-collective-step latency `alpha`.  The data-parallel extent of
the cluster is `prod(ways)`.  Optional `DeviceGroup`s describe
heterogeneous sub-fleets (their own `hbm_bytes` / `peak_flops`), which
partition the cluster at the outermost level.

Collectives are priced with a *hierarchical ring*: a collective
spanning levels `[0, k)` runs one ring pass per level, each pass over
that level's `ways` with that level's `alpha` and `bandwidth`, moving
only the chunk already aggregated below it.  For a tensor of B bytes
fully gathered over a span of N devices, the pass at level l (ways w_l,
prefix product P_l = prod_{j<l} w_j) costs

    (w_l - 1) * (alpha_l + B * P_l / (N * bw_l))

which degenerates to the classic flat ring `(n-1)(alpha + B/n/bw)` at
depth 1.  `_span_terms` returns the `(sum of (w-1)*alpha, per-byte
beta)` pair so the cost model can table-ize the prices.

The legacy flat model is the depth-2 degenerate case:
`ClusterSpec.from_flat(device, mesh)` maps the mesh's `data` axis to an
inner level at `ici_bw` and its `pod` axis to an outer level at
`dci_bw` — and on single-pod meshes every hierarchical price collapses
to the exact pre-existing flat formula (asserted byte-identical by
tests/test_topology.py).

Sharding modes generalize to "ZDP at level k": shard the model states
across the innermost k levels, gather over that span, and all-reduce
gradients across the remaining outer extent.  `ZDP` is level `depth`
(shard everything), the legacy `ZDP_POD` is level 1 of a depth-2 spec.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

from repro.configs.base import DeviceInfo, MeshConfig

# canonical sharding-mode names (shared with core.cost_model)
DP = "DP"
ZDP = "ZDP"
ZDP_POD = "ZDP_POD"          # depth-2 alias for "ZDP at level 1"
LEVEL_PREFIX = "ZDP@"        # generalized: "ZDP@k" shards levels [0, k)


def level_mode(k: int) -> str:
    """Mode name for ZDP sharded across the innermost k levels."""
    return f"{LEVEL_PREFIX}{k}"


def parse_level_mode(mode: str) -> Optional[int]:
    """Span (in levels) of a 'ZDP@k' mode name, None if not one."""
    if mode.startswith(LEVEL_PREFIX):
        return int(mode[len(LEVEL_PREFIX):])
    return None


@dataclass(frozen=True)
class ClusterLevel:
    """One rung of the bandwidth hierarchy (innermost levels are the
    fastest: chip-to-chip ICI / NVLink; outer levels are node, pod,
    cluster interconnects)."""

    name: str
    ways: int                 # fan-out at this level
    bandwidth: float          # bytes/s per link at this level
    alpha: float = 1e-6      # per-collective-step latency (s)
    overlap: float = 0.0     # fraction of this level's comm hideable
                             # under compute (0 = serial legacy model)


@dataclass(frozen=True)
class DeviceGroup:
    """A heterogeneous sub-fleet: `n_devices` devices sharing one HBM
    capacity and peak-FLOPs figure.  Groups partition the cluster at
    the outermost level (mixed generations *within* a node are out of
    scope).  `hbm_bytes` is the per-device memory budget the planner
    may fill; `peak_flops=0` inherits the base `DeviceInfo`."""

    name: str
    n_devices: int
    hbm_bytes: float
    peak_flops: float = 0.0


@dataclass(frozen=True)
class ClusterSpec:
    """Hierarchical device information for the planner.

    `levels` are innermost-first; the spec describes the *data-parallel
    extent* seen by one search (TP/PP spans are carved off with
    `consume_inner` / `consume_outer` before the DP search runs).
    """

    levels: Tuple[ClusterLevel, ...]
    device: DeviceInfo = field(default_factory=DeviceInfo)
    groups: Tuple[DeviceGroup, ...] = ()

    def __post_init__(self):
        if not self.levels:
            raise ValueError("ClusterSpec needs at least one level")
        names = [l.name for l in self.levels]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate level names: {names}")
        for l in self.levels:
            if l.ways < 1 or l.bandwidth <= 0:
                raise ValueError(f"bad level {l}")
            if not 0.0 <= l.overlap <= 1.0:
                raise ValueError(
                    f"level {l.name}: overlap {l.overlap} outside [0, 1]")
        # a ways > 1 level outside a ways == 1 level would break the
        # level-index <-> mesh-axis correspondence (mesh_config drops
        # ways == 1 axes, and sharding maps "ZDP@k" to the k innermost
        # data axes); degenerate levels may only trail outermost
        seen_one = False
        for l in self.levels:
            if l.ways == 1:
                seen_one = True
            elif seen_one:
                raise ValueError(
                    f"level {l.name} (ways {l.ways}) appears outside a "
                    f"ways-1 level; fold degenerate levels outward")
        if self.groups:
            n = sum(g.n_devices for g in self.groups)
            if n != self.n_devices:
                raise ValueError(
                    f"groups cover {n} devices, cluster has "
                    f"{self.n_devices}")

    # -- shape ---------------------------------------------------------------

    @property
    def depth(self) -> int:
        return len(self.levels)

    @property
    def n_devices(self) -> int:
        return math.prod(l.ways for l in self.levels)

    def span_ways(self, k: int) -> int:
        """Devices inside one span of the innermost k levels."""
        return math.prod(l.ways for l in self.levels[:k])

    # -- comm/compute overlap ------------------------------------------------

    @property
    def overlaps(self) -> Tuple[float, ...]:
        """Per-level overlap factors, innermost-first (parallel to
        `levels`)."""
        return tuple(l.overlap for l in self.levels)

    @property
    def has_overlap(self) -> bool:
        """True when any level can hide communication under compute —
        the cost model only leaves its serial-sum (legacy, golden-
        pinned) path when this is set."""
        return any(l.overlap > 0.0 for l in self.levels)

    def with_overlap(self, overlap) -> "ClusterSpec":
        """Copy of this spec with overlap factors replaced: a scalar
        applies to every level, a mapping ``{level_name: factor}``
        sets only the named levels (others keep their current value).
        ``with_overlap(0.0)`` recovers the serial cost model."""
        if isinstance(overlap, (int, float)):
            by_name = {l.name: float(overlap) for l in self.levels}
        else:
            by_name = dict(overlap)
            unknown = set(by_name) - {l.name for l in self.levels}
            if unknown:
                raise ValueError(
                    f"unknown levels {sorted(unknown)}; have "
                    f"{[l.name for l in self.levels]}")
        levels = tuple(
            dataclasses.replace(l, overlap=by_name[l.name])
            if l.name in by_name else l for l in self.levels)
        return dataclasses.replace(self, levels=levels)

    # -- calibration ---------------------------------------------------------

    def with_links(self, links) -> "ClusterSpec":
        """Copy of this spec with measured (alpha, bandwidth) pairs
        substituted for the datasheet constants — the ClusterSpec half
        of attaching a `CalibrationProfile` to a `CostEnv`.

        ``links`` is an iterable of objects with ``.level``,
        ``.alpha`` and ``.bandwidth`` attributes
        (`repro.calibrate.profile.LinkCalibration`; duck-typed so this
        module stays import-free of the calibrate package).  Links are
        matched to levels by name; if *no* link name matches any level
        the links are assigned positionally innermost-first instead
        (a profile fitted on a flat "data"/"pod" mesh still prices a
        "node"/"cluster" spec).  Unmatched levels keep their datasheet
        constants."""
        links = list(links)
        if not links:
            return self
        level_names = {l.name for l in self.levels}
        by_name = {ln.level: ln for ln in links}
        if not (set(by_name) & level_names):
            by_name = {lvl.name: ln
                       for lvl, ln in zip(self.levels, links)}
        levels = tuple(
            dataclasses.replace(l, alpha=by_name[l.name].alpha,
                                bandwidth=by_name[l.name].bandwidth)
            if l.name in by_name else l
            for l in self.levels)
        return dataclasses.replace(self, levels=levels)

    # -- sharding modes ------------------------------------------------------

    @property
    def shard_levels(self) -> List[int]:
        """Intermediate spans k (1 <= k < depth) that differ from both
        DP and full ZDP — the searchable "ZDP at level k" items.  Spans
        whose ways collapse to 1 or to the full extent are skipped."""
        out = []
        n = self.n_devices
        for k in range(1, self.depth):
            w = self.span_ways(k)
            if 1 < w < n and (not out or w != self.span_ways(out[-1])):
                out.append(k)
        return out

    @property
    def mode_names(self) -> Tuple[str, ...]:
        """Ordered decision-mode names: DP, full ZDP, then one entry
        per intermediate level.  Depth-2 specs keep the legacy
        ``ZDP_POD`` name (byte-compatible plans); deeper specs use
        ``ZDP@k``.  The list always includes the depth-2 triple for a
        depth-<=2 spec so evaluator column layouts stay stable."""
        if self.depth <= 2:
            return (DP, ZDP, ZDP_POD)
        names = [DP, ZDP]
        names += [level_mode(k) for k in range(1, self.depth)]
        return tuple(names)

    def span_mode(self, k: int) -> str:
        """Canonical mode name for sharding across the innermost k
        levels (inverse of `mode_span`)."""
        if not 0 < k <= self.depth:
            raise ValueError(f"span {k} out of range for depth "
                             f"{self.depth}")
        if k == self.depth:
            return ZDP
        if self.depth <= 2 and k == 1:
            return ZDP_POD
        return level_mode(k)

    def mode_span(self, mode: str) -> int:
        """Levels [0, span) a mode's shard extent covers (0 for DP)."""
        if mode == DP:
            return 0
        if mode == ZDP:
            return self.depth
        if mode == ZDP_POD:
            return min(1, self.depth)
        k = parse_level_mode(mode)
        if k is None or not 0 < k <= self.depth:
            raise ValueError(f"unknown mode {mode!r} for depth "
                             f"{self.depth}")
        return k

    def shard_ways(self, mode: str) -> float:
        """State divisor for a mode.  Full-span ZDP on a heterogeneous
        cluster uses capacity-weighted sharding: device d holds states
        proportional to its HBM, so the *binding* (smallest-memory)
        group holds `states * hbm_min / total_hbm` — an effective
        divisor of `total_hbm / hbm_min >= n_devices`."""
        k = self.mode_span(mode)
        if k == 0:
            return 1.0
        if k == self.depth and self.groups:
            return self.total_hbm / self.min_hbm
        return float(self.span_ways(k))

    # -- heterogeneous groups ------------------------------------------------

    @property
    def total_hbm(self) -> float:
        if self.groups:
            return sum(g.n_devices * g.hbm_bytes for g in self.groups)
        return self.n_devices * self.device.hbm_bytes

    @property
    def min_hbm(self) -> float:
        if self.groups:
            return min(g.hbm_bytes for g in self.groups)
        return self.device.hbm_bytes

    def memory_limit(self, default: float) -> float:
        """Per-device memory budget the search must respect.  Uniform
        clusters use the caller's limit; heterogeneous clusters judge
        feasibility at the worst group (its `hbm_bytes` IS its budget
        — encode headroom by shrinking the group's `hbm_bytes`), which
        is exact under capacity-weighted sharding: group g's state
        share scales with hbm_g while its budget does too, so the
        smallest group binds first."""
        if self.groups:
            return self.min_hbm
        return default

    @property
    def effective_peak_flops(self) -> float:
        """Synchronous training runs at the slowest group's pace."""
        flops = [g.peak_flops for g in self.groups if g.peak_flops > 0]
        return min(flops) if flops else self.device.peak_flops

    # -- hierarchical ring pricing -------------------------------------------

    def _span_terms(self, k_lo: int, k_hi: int) -> Tuple[float, float]:
        """(alpha_sum, beta_per_byte) of ONE hierarchical ring pass
        over levels [k_lo, k_hi).  beta multiplies the bytes of the
        tensor as fully held over the span (for a gather: the gathered
        size; for the outer grad all-reduce: the shard)."""
        n = math.prod(l.ways for l in self.levels[k_lo:k_hi])
        if n <= 1:
            return 0.0, 0.0
        alpha_sum = 0.0
        beta = 0.0
        prefix = 1
        for l in self.levels[k_lo:k_hi]:
            if l.ways > 1:
                alpha_sum += (l.ways - 1) * l.alpha
                beta += (l.ways - 1) * prefix / (n * l.bandwidth)
            prefix *= l.ways
        return alpha_sum, beta

    def gather_terms(self, k: int) -> Tuple[float, float]:
        """One ring pass of a gather/scatter over the innermost k
        levels (a ZDP-at-level-k parameter all-gather)."""
        return self._span_terms(0, k)

    def outer_terms(self, k: int) -> Tuple[float, float]:
        """One ring pass across the outer extent (levels [k, depth)) —
        the replicated-gradient all-reduce of a level-k shard.  beta is
        per byte of the shard."""
        return self._span_terms(k, self.depth)

    def span_rings(self, k_lo: int,
                   k_hi: int) -> List[Tuple[int, float, float, int]]:
        """The ring passes of one hierarchical collective over levels
        [k_lo, k_hi), as [(ways, alpha, bandwidth, prefix)] per
        (ways > 1) level — `prefix` is the product of ways of the
        preceding levels *within the span*.  One pass over the span
        moving B fully-held bytes costs

            sum_rings (ways - 1) * (alpha + B * prefix / n_span / bw)

        Cost-model code iterates these rings and keeps the exact
        floating-point shape of the legacy flat formula, so a depth-2
        single-pod span prices bit-identically to the pre-topology
        engine (one ring: (n-1) * (alpha + B / n / bw))."""
        rings: List[Tuple[int, float, float, int]] = []
        prefix = 1
        for l in self.levels[k_lo:k_hi]:
            if l.ways > 1:
                rings.append((l.ways, l.alpha, l.bandwidth, prefix))
            prefix *= l.ways
        return rings

    def gather_rings(self, k: int) -> List[Tuple[int, float, float, int]]:
        return self.span_rings(0, k)

    def outer_rings(self, k: int) -> List[Tuple[int, float, float, int]]:
        return self.span_rings(k, self.depth)

    def span_ring_levels(self, k_lo: int, k_hi: int) -> List[int]:
        """Absolute level index of each ring `span_rings(k_lo, k_hi)`
        returns (the ways-1 levels are skipped by both), so timeline
        cost code can bucket each ring's seconds under the level whose
        `overlap` factor governs it."""
        return [k_lo + i for i, l in enumerate(self.levels[k_lo:k_hi])
                if l.ways > 1]

    def gather_ring_levels(self, k: int) -> List[int]:
        return self.span_ring_levels(0, k)

    def outer_ring_levels(self, k: int) -> List[int]:
        return self.span_ring_levels(k, self.depth)

    def inner_span_terms(self, n: int) -> Tuple[float, float]:
        """(alpha_sum, beta_per_byte) of one ring pass over the
        innermost `n` devices, cutting through a level if `n` only
        partially covers it (used to price TP all-reduces placed on the
        innermost links).  `n` must divide into the level structure."""
        if n <= 1:
            return 0.0, 0.0
        rem = n
        prefix = 1
        alpha_sum = 0.0
        beta = 0.0
        for l in self.levels:
            if rem <= 1:
                break
            r = min(l.ways, rem)
            if rem % r or (r < l.ways and l.ways % r):
                raise ValueError(
                    f"span {n} does not fit the level structure "
                    f"{[l.ways for l in self.levels]}")
            if r > 1:
                alpha_sum += (r - 1) * l.alpha
                beta += (r - 1) * prefix / (n * l.bandwidth)
            prefix *= r
            rem //= r
        if rem > 1:
            raise ValueError(f"span {n} exceeds cluster "
                             f"({self.n_devices} devices)")
        return alpha_sum, beta

    def ring_time(self, nbytes: float, k: int,
                  alpha_scale: float = 1.0) -> float:
        """Seconds of one hierarchical ring pass gathering `nbytes`
        over the innermost k levels."""
        a, b = self.gather_terms(k)
        return a * alpha_scale + nbytes * b

    # -- carving TP / PP spans off the hierarchy -----------------------------

    def consume_inner(self, ways: int) -> "ClusterSpec":
        """Residual spec after assigning the innermost `ways` devices
        of every span to another axis (tensor parallelism).  Raises
        ValueError when `ways` does not divide the level structure —
        such factorizations are inadmissible on this topology."""
        if ways <= 1:
            return self
        levels: List[ClusterLevel] = []
        rem = ways
        for l in self.levels:
            if rem <= 1:
                levels.append(l)
            elif l.ways <= rem:
                if rem % l.ways:
                    raise ValueError(
                        f"tp={ways} does not divide level {l.name} "
                        f"(ways {l.ways})")
                rem //= l.ways       # level fully consumed
            else:
                if l.ways % rem:
                    raise ValueError(
                        f"tp={ways} does not divide level {l.name} "
                        f"(ways {l.ways})")
                levels.append(dataclasses.replace(l, ways=l.ways // rem))
                rem = 1
        if rem > 1:
            raise ValueError(f"tp={ways} exceeds cluster size")
        if not levels:
            levels = [dataclasses.replace(self.levels[0], ways=1)]
        return dataclasses.replace(self, levels=tuple(levels),
                                   groups=self._scaled_groups(ways))

    def consume_outer(self, ways: int) -> "ClusterSpec":
        """Residual spec after assigning the outermost `ways`-way split
        to another axis (pipeline parallelism)."""
        if ways <= 1:
            return self
        levels: List[ClusterLevel] = []
        rem = ways
        for l in reversed(self.levels):
            if rem <= 1:
                levels.append(l)
            elif l.ways <= rem:
                if rem % l.ways:
                    raise ValueError(
                        f"pp={ways} does not divide level {l.name} "
                        f"(ways {l.ways})")
                rem //= l.ways
            else:
                if l.ways % rem:
                    raise ValueError(
                        f"pp={ways} does not divide level {l.name} "
                        f"(ways {l.ways})")
                levels.append(dataclasses.replace(l, ways=l.ways // rem))
                rem = 1
        if rem > 1:
            raise ValueError(f"pp={ways} exceeds cluster size")
        levels.reverse()
        if not levels:
            levels = [dataclasses.replace(self.levels[0], ways=1)]
        # PP stages split the fleet at the outermost level, so each
        # stage keeps groups only if they still tile the residue; a
        # heterogeneous fleet split across stages keeps the worst
        # group's budget (conservative).
        return dataclasses.replace(self, levels=tuple(levels),
                                   groups=self._scaled_groups(ways))

    def _scaled_groups(self, consumed: int) -> Tuple[DeviceGroup, ...]:
        if not self.groups:
            return ()
        groups = []
        for g in self.groups:
            if g.n_devices % consumed:
                # group no longer tiles the residue: collapse to the
                # binding (min-HBM) group for the whole residue
                worst = min(self.groups, key=lambda x: x.hbm_bytes)
                n = self.n_devices // consumed
                return (dataclasses.replace(worst, n_devices=n),)
            groups.append(dataclasses.replace(
                g, n_devices=g.n_devices // consumed))
        return tuple(groups)

    # -- degraded fleets (device loss) ---------------------------------------

    def degrade(self, *, group: Optional[str] = None,
                level: Optional[str] = None,
                ways: int = 1) -> "ClusterSpec":
        """The post-failure spec after losing part of the fleet.

        Two forms:

          * ``degrade(group="large")`` — a heterogeneous `DeviceGroup`
            dies entirely (groups partition at the outermost level, so
            the lost devices must tile whole outermost spans);
          * ``degrade(level="pod", ways=2)`` — lose `ways` spans of the
            named level (default: the outermost ways > 1 level).

        The survivors keep their level structure; sharding capacity
        can only shrink (`shard_ways` of every mode is non-increasing,
        `total_hbm` strictly decreases), so a stale plan's per-device
        memory never *improves* on the degraded spec — which is why
        the supervisor must re-verify feasibility before resuming.
        """
        if group is not None and level is not None:
            raise ValueError("degrade by group OR by level, not both")
        outer = max((i for i, l in enumerate(self.levels) if l.ways > 1),
                    default=None)
        if outer is None:
            raise ValueError("cannot degrade a single-device cluster")
        if group is not None:
            g = next((x for x in self.groups if x.name == group), None)
            if g is None:
                raise ValueError(
                    f"no group {group!r} in "
                    f"{[x.name for x in self.groups]}")
            inner_span = self.n_devices // self.levels[outer].ways
            if g.n_devices % inner_span:
                raise ValueError(
                    f"group {group!r} ({g.n_devices} devices) does not "
                    f"tile the outermost spans of {inner_span}")
            lost_ways = g.n_devices // inner_span
            survivors = tuple(x for x in self.groups if x.name != group)
            return self._drop_ways(outer, lost_ways, survivors)
        idx = outer
        if level is not None:
            named = [i for i, l in enumerate(self.levels)
                     if l.name == level]
            if not named:
                raise ValueError(
                    f"no level {level!r} in "
                    f"{[l.name for l in self.levels]}")
            idx = named[0]
        if ways < 1 or ways >= self.levels[idx].ways:
            raise ValueError(
                f"cannot lose {ways} of {self.levels[idx].ways} spans "
                f"at level {self.levels[idx].name!r} (need at least "
                f"one survivor)")
        lost_dev = ways * (self.n_devices // self.levels[idx].ways)
        groups = self._degraded_groups(self.n_devices - lost_dev)
        return self._drop_ways(idx, ways, groups)

    def _drop_ways(self, idx: int, lost_ways: int,
                   groups: Tuple[DeviceGroup, ...]) -> "ClusterSpec":
        l = self.levels[idx]
        if lost_ways >= l.ways:
            raise ValueError(
                f"losing {lost_ways} of {l.ways} spans at level "
                f"{l.name!r} leaves no survivors")
        levels = list(self.levels)
        levels[idx] = dataclasses.replace(l, ways=l.ways - lost_ways)
        # a level collapsing to ways == 1 must not strand an outer
        # ways > 1 level (the post-init invariant): fold it outward by
        # keeping it where it is only if nothing wider sits outside
        if levels[idx].ways == 1 and any(
                x.ways > 1 for x in levels[idx + 1:]):
            levels = levels[:idx] + levels[idx + 1:] + [levels[idx]]
        return dataclasses.replace(self, levels=tuple(levels),
                                   groups=groups)

    def _degraded_groups(self, n_new: int) -> Tuple[DeviceGroup, ...]:
        """Survivor groups after an anonymous (level-wise) loss: scale
        proportionally when the loss tiles every group, else collapse
        to the binding (min-HBM) group for the whole residue."""
        if not self.groups:
            return ()
        n_old = self.n_devices
        if all(g.n_devices * n_new % n_old == 0 for g in self.groups):
            return tuple(dataclasses.replace(
                g, n_devices=g.n_devices * n_new // n_old)
                for g in self.groups)
        worst = min(self.groups, key=lambda g: g.hbm_bytes)
        return (dataclasses.replace(worst, n_devices=n_new),)

    def pp_boundary_bandwidth(self, pp: int) -> float:
        """Bandwidth of the link a pipeline-stage boundary crosses when
        PP is placed across the outermost (slowest) levels: the
        innermost level the pp-way split reaches."""
        if pp <= 1:
            return self.levels[0].bandwidth
        rem = pp
        bw = self.levels[-1].bandwidth
        for l in reversed(self.levels):
            if rem <= 1:
                break
            if l.ways > 1:
                bw = l.bandwidth
            rem = max(1, rem // max(1, l.ways))
        return bw

    def pp_boundary_overlap(self, pp: int) -> float:
        """Overlap factor of the link a pipeline-stage boundary
        crosses (same walk as `pp_boundary_bandwidth`): how much of a
        stage's boundary send can hide under the next microbatch's
        compute."""
        if pp <= 1:
            return self.levels[0].overlap
        rem = pp
        ov = self.levels[-1].overlap
        for l in reversed(self.levels):
            if rem <= 1:
                break
            if l.ways > 1:
                ov = l.overlap
            rem = max(1, rem // max(1, l.ways))
        return ov

    # -- flat-model interop --------------------------------------------------

    @classmethod
    def from_flat(cls, device: DeviceInfo,
                  mesh: MeshConfig) -> "ClusterSpec":
        """The depth-2 degenerate case: the mesh's `data` axis becomes
        an inner level at `ici_bw`, its `pod` axis an outer level at
        `dci_bw`.  On single-pod meshes every hierarchical price
        collapses to the legacy flat-ring formula exactly."""
        n_local = 1
        n_pods = 1
        for s, a in zip(mesh.shape, mesh.axes):
            if a == "data":
                n_local *= s
            elif a == "pod":
                n_pods *= s
        if n_local == 1 and n_pods > 1:
            # degenerate data axis: the pod axis is the whole (dci-
            # speed) data extent — fold it inward so no ways > 1 level
            # sits outside a ways-1 level
            return cls(levels=(
                ClusterLevel("data", n_pods, device.dci_bw, device.alpha,
                             device.overlap),
                ClusterLevel("pod", 1, device.dci_bw, device.alpha,
                             device.overlap)),
                device=device)
        return cls(levels=(
            ClusterLevel("data", n_local, device.ici_bw, device.alpha,
                         device.overlap),
            ClusterLevel("pod", n_pods, device.dci_bw, device.alpha,
                         device.overlap)),
            device=device)

    @classmethod
    def from_device(cls, device: DeviceInfo,
                    n_devices: int) -> "ClusterSpec":
        """Infer a hierarchy for `n_devices` from a `DeviceInfo`: if
        the device declares `devices_per_node` and the fleet spans
        several nodes, build (node @ ici, cluster @ dci); otherwise a
        single flat level at `ici_bw` (the legacy assumption)."""
        dpn = getattr(device, "devices_per_node", 0) or 0
        if dpn and 1 <= dpn < n_devices and n_devices % dpn == 0:
            return cls(levels=(
                ClusterLevel("node", dpn, device.ici_bw, device.alpha,
                             device.overlap),
                ClusterLevel("cluster", n_devices // dpn, device.dci_bw,
                             device.alpha, device.overlap)),
                device=device)
        return cls(levels=(
            ClusterLevel("data", n_devices, device.ici_bw, device.alpha,
                         device.overlap),),
            device=device)

    def to_flat(self) -> Tuple[DeviceInfo, MeshConfig]:
        """Collapse to the legacy flat model: innermost bandwidth as
        ICI, the *slowest outer* bandwidth as DCI, all outer ways
        folded into one pod axis.  This is what a flat planner sees of
        a deep topology — `benchmarks/topology_sweep.py` quantifies
        what that collapse costs."""
        inner = self.levels[0]
        outer_ways = math.prod(l.ways for l in self.levels[1:])
        outer_bw = min((l.bandwidth for l in self.levels[1:]
                        if l.ways > 1), default=inner.bandwidth)
        device = dataclasses.replace(
            self.device, ici_bw=inner.bandwidth, dci_bw=outer_bw,
            alpha=inner.alpha)
        if outer_ways > 1:
            mesh = MeshConfig((outer_ways, inner.ways, 1),
                              ("pod", "data", "model"))
        else:
            mesh = MeshConfig((inner.ways, 1), ("data", "model"))
        return device, mesh

    def mesh_config(self, model_parallel: int = 1,
                    pipeline_parallel: int = 1) -> MeshConfig:
        """Logical mesh for this spec: one axis per (ways > 1) level,
        outermost first, then `model` / `pipe`.  Depth-2 specs emit
        the legacy ('pod', 'data', 'model') layout."""
        shape: List[int] = []
        axes: List[str] = []
        for l in reversed(self.levels):
            if l.ways > 1:
                shape.append(l.ways)
                axes.append(l.name)
        if not shape:
            shape, axes = [1], [self.levels[0].name]
        shape.append(model_parallel)
        axes.append("model")
        if pipeline_parallel > 1:
            shape.append(pipeline_parallel)
            axes.append("pipe")
        return MeshConfig(tuple(shape), tuple(axes))

    def summary(self) -> str:
        lv = " > ".join(
            f"{l.name}x{l.ways}@{l.bandwidth / 1e9:.0f}GB/s"
            for l in reversed(self.levels))
        gr = ""
        if self.groups:
            gr = " groups[" + ", ".join(
                f"{g.name}:{g.n_devices}x{g.hbm_bytes / 2**30:.0f}GiB"
                for g in self.groups) + "]"
        return f"cluster[{self.n_devices}] {lv}{gr}"


# ---------------------------------------------------------------------------
# Presets: the topologies the benchmarks sweep
# ---------------------------------------------------------------------------

def tpu_multipod(n_pods: int, pod_size: int,
                 device: Optional[DeviceInfo] = None) -> ClusterSpec:
    """TPU fleet: `pod_size` chips on ICI per pod, pods on DCI."""
    dev = device or DeviceInfo()
    return ClusterSpec(levels=(
        ClusterLevel("data", pod_size, dev.ici_bw, dev.alpha, dev.overlap),
        ClusterLevel("pod", n_pods, dev.dci_bw, dev.alpha, dev.overlap)),
        device=dev)


def gpu_cluster(n_nodes: int, gpus_per_node: int = 8,
                device: Optional[DeviceInfo] = None,
                nvlink_bw: float = 450e9, ib_bw: float = 50e9,
                spine_nodes: int = 0,
                spine_bw: float = 25e9) -> ClusterSpec:
    """GPU fleet: NVLink inside the node, InfiniBand across nodes, and
    optionally a third (oversubscribed spine) level grouping
    `spine_nodes` nodes per leaf switch."""
    dev = device or DeviceInfo.preset("a100-80g")
    dev = dataclasses.replace(dev, ici_bw=nvlink_bw, dci_bw=ib_bw)
    levels = [ClusterLevel("node", gpus_per_node, nvlink_bw, dev.alpha,
                           dev.overlap)]
    if spine_nodes and spine_nodes < n_nodes:
        if n_nodes % spine_nodes:
            raise ValueError("spine_nodes must divide n_nodes")
        levels.append(ClusterLevel("rack", spine_nodes, ib_bw, dev.alpha,
                                   dev.overlap))
        levels.append(ClusterLevel("spine", n_nodes // spine_nodes,
                                   spine_bw, dev.alpha, dev.overlap))
    else:
        levels.append(ClusterLevel("rack", n_nodes, ib_bw, dev.alpha,
                                   dev.overlap))
    return ClusterSpec(levels=tuple(levels), device=dev)


def mixed_memory_fleet(n_small: int, small_hbm_gib: float,
                       n_large: int, large_hbm_gib: float,
                       pod_size: int,
                       device: Optional[DeviceInfo] = None) -> ClusterSpec:
    """Mixed-generation fleet: `n_small` low-memory and `n_large`
    high-memory devices, pods of `pod_size` on ICI, pods on DCI.
    Groups partition at the pod boundary."""
    dev = device or DeviceInfo()
    n = n_small + n_large
    if n % pod_size:
        raise ValueError("pod_size must divide the fleet")
    return ClusterSpec(levels=(
        ClusterLevel("data", pod_size, dev.ici_bw, dev.alpha, dev.overlap),
        ClusterLevel("pod", n // pod_size, dev.dci_bw, dev.alpha,
                     dev.overlap)),
        device=dev,
        groups=(
            DeviceGroup("small", n_small, small_hbm_gib * 2**30),
            DeviceGroup("large", n_large, large_hbm_gib * 2**30)))
